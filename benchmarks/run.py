"""Benchmark driver — one section per paper table/figure + kernels +
roofline. Run: PYTHONPATH=src python -m benchmarks.run"""
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (fig1_rates, fig2_throughput, kernels_micro,
                            kvsharer_bench, roofline, serving_continuous,
                            table1_selective, table2_quant,
                            table3_attention)
    sections = [
        ("Table1: selective compression (survey §2)", table1_selective.run),
        ("Table1b: KVSharer layer sharing (survey §2 [10])",
         kvsharer_bench.run),
        ("Table2: quantization compression (survey §3)", table2_quant.run),
        ("Table3: attention/layer-budget compression (survey §4)",
         table3_attention.run),
        ("Fig1: inference-rate improvement", fig1_rates.run),
        ("Fig2: end-to-end engine throughput (survey §5/§6)",
         fig2_throughput.run),
        ("Serving: continuous-batching metrics snapshot "
         "(BENCH_serving.json)", serving_continuous.run),
        ("Kernels: micro-benchmarks (interpret mode)", kernels_micro.run),
        ("Roofline: dry-run derived terms (single-pod)", roofline.run),
    ]
    for title, fn in sections:
        print(f"\n=== {title} ===", flush=True)
        t0 = time.perf_counter()
        try:
            print(fn())
        except Exception as e:  # noqa: BLE001
            print(f"SECTION FAILED: {e!r}")
            raise
        print(f"[{time.perf_counter() - t0:.1f}s]", flush=True)


if __name__ == "__main__":
    main()
