"""Kernel micro-benchmarks (interpret-mode on CPU: correctness-scale
timings; the real perf story is the roofline + §Perf HLO analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.kvquant import kernel as kq
from repro.kernels.kvquant import ref as kq_ref
from repro.kernels.decode_qattn import kernel as dq
from repro.kernels.flash_prefill import kernel as fp


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)                           # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / n * 1e6


def run() -> str:
    rows = ["name,us_per_call,derived"]
    B, S, H, D, G = 1, 512, 4, 64, 64
    k = jax.random.normal(jax.random.key(0), (B, S, H, D), jnp.float32)
    us = _time(kq.kquant_pallas, k, bits=4, group=G, interpret=True)
    gbps = k.size * 4 / us / 1e3
    rows.append(f"kvquant_k4,{us:.0f},{gbps:.2f}GB/s-interp")

    kq_, ks_, kz_ = kq_ref.kquant_ref(k, 4, G)
    vq_, vs_, vz_ = kq_ref.vquant_ref(k, 4)
    q = jax.random.normal(jax.random.key(1), (B, H * 2, D), jnp.float32)
    bias = jnp.zeros((B, S))
    us = _time(dq.decode_qattn_pallas, q, kq_, ks_, kz_, vq_, vs_, vz_, bias,
               bits=4, group=G, block_s=128, interpret=True)
    rows.append(f"decode_qattn4,{us:.0f},S={S}")

    T = 256
    qf = jax.random.normal(jax.random.key(2), (B, T, H, D), jnp.float32)
    kf = jax.random.normal(jax.random.key(3), (B, T, H, D), jnp.float32)
    us = _time(fp.flash_prefill_pallas, qf, kf, kf, bq=64, bk=64,
               interpret=True)
    rows.append(f"flash_prefill,{us:.0f},T={T}")
    return "\n".join(rows)


if __name__ == "__main__":
    print(run())
