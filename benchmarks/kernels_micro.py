"""Kernel micro-benchmarks (interpret-mode on CPU: correctness-scale
timings; the real perf story is the roofline + §Perf HLO analysis).

The headline table is `decode_paths`: one decode-attention step over the
same compressed `LayerKV` through the two paths —

  * **materialize**: unpack + dequantize the whole main store to the
    model dtype, concatenate the ring, XLA attention (the oracle);
  * **fused**: the Pallas kernel reads packed codes + scales and the
    ring directly (`repro.kernels.decode_qattn`).

plus the analytic HBM bytes each path moves per step per layer. The
bytes column is the survey's point: the fused path's cache read scales
with bits/16 while the materialize path always moves (and round-trips)
16-bit traffic.

    PYTHONPATH=src python benchmarks/kernels_micro.py
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import cache as kvcache
from repro.core.cache import CacheSpec
from repro.kernels.kvquant import kernel as kq
from repro.kernels.kvquant import ref as kq_ref
from repro.kernels.decode_qattn import kernel as dq
from repro.kernels.flash_prefill import kernel as fp
from repro.nn import attention as attn


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)                           # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / n * 1e6


def decode_step_bytes(spec: CacheSpec, S: int, W: int, H: int, D: int,
                      fused: bool) -> float:
    """Analytic HBM cache traffic of one decode-attention step per layer
    per sequence (reads; plus the materialize path's dequant round-trip)."""
    if spec.quantized:
        codes = 2 * S * H * D * spec.bits / 8          # packed K + V
        k_meta = (S // spec.group) * H * D * 2 * 4.0   # scale + zero f32
        v_meta = S * H * 2 * 4.0
        ring = 2 * W * H * D * 2.0                     # bf16 residual
        read = codes + k_meta + v_meta + ring
        if not fused:
            # dequantized bf16 main store written then read back by attn
            read += 2 * (2 * S * H * D * 2.0)
        return read
    dense = 2 * (S + W) * H * D * 2.0
    return dense


def decode_paths_rows(rows):
    B, H, D, Gq = 4, 4, 64, 2
    S, W, S_p = 256, 16, 384
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    k = jax.random.normal(ks[0], (B, S_p, H, D), jnp.float32)
    v = jax.random.normal(ks[1], (B, S_p, H, D), jnp.float32)
    mass = jax.random.uniform(ks[2], (B, S_p))
    q = jax.random.normal(ks[3], (B, 1, H * Gq, D), jnp.bfloat16)

    rows.append("decode_paths: bits,materialize_us,fused_us,"
                "mat_bytes,fused_bytes,byte_ratio")
    for bits in (2, 4, 8, 16):
        spec = CacheSpec(budget=S, window=W, bits=bits, group=W,
                         policy="streaming")
        lc = kvcache.compress_prompt(spec, k, v, mass, dtype=jnp.bfloat16)

        def mat(lc, q):
            return attn.decode_attention(q, lc, spec, dtype=jnp.bfloat16,
                                         use_kernels=False)

        def fus(lc, q):
            return attn.decode_attention(q, lc, spec, dtype=jnp.bfloat16,
                                         use_kernels=True, interpret=True)

        us_m = _time(jax.jit(mat), lc, q)
        us_f = _time(jax.jit(fus), lc, q)
        b_m = decode_step_bytes(spec, S, W, H, D, fused=False)
        b_f = decode_step_bytes(spec, S, W, H, D, fused=True)
        rows.append(f"decode_paths,{bits},{us_m:.0f},{us_f:.0f},"
                    f"{b_m:.0f},{b_f:.0f},{b_f / b_m:.3f}")
    return rows


def run() -> str:
    rows = ["name,us_per_call,derived"]
    B, S, H, D, G = 1, 512, 4, 64, 64
    k = jax.random.normal(jax.random.key(0), (B, S, H, D), jnp.float32)
    us = _time(kq.kquant_pallas, k, bits=4, group=G, interpret=True)
    gbps = k.size * 4 / us / 1e3
    rows.append(f"kvquant_k4,{us:.0f},{gbps:.2f}GB/s-interp")

    kq_, ks_, kz_ = kq_ref.kquant_ref(k, 4, G)
    vq_, vs_, vz_ = kq_ref.vquant_ref(k, 4)
    q = jax.random.normal(jax.random.key(1), (B, H * 2, D), jnp.float32)
    bias = jnp.zeros((B, S))
    us = _time(dq.decode_qattn_pallas, q, kq_, ks_, kz_, vq_, vs_, vz_, bias,
               bits=4, group=G, block_s=128, interpret=True)
    rows.append(f"decode_qattn4,{us:.0f},S={S}")

    T = 256
    qf = jax.random.normal(jax.random.key(2), (B, T, H, D), jnp.float32)
    kf = jax.random.normal(jax.random.key(3), (B, T, H, D), jnp.float32)
    us = _time(fp.flash_prefill_pallas, qf, kf, kf, bq=64, bk=64,
               interpret=True)
    rows.append(f"flash_prefill,{us:.0f},T={T}")

    return "\n".join(decode_paths_rows(rows))


if __name__ == "__main__":
    print(run())
