"""Shared benchmark harness: a small paper-family model (LLaMa-arch), a
fixed prompt set, timing helpers, and quality proxies.

Quality proxies (CPU, untrained weights — see EXPERIMENTS.md §Method):
  * KL(full ‖ compressed) of next-token distributions during decode —
    measures representational distortion introduced by the cache policy;
  * greedy-token agreement with the full-cache engine;
  * analytic compression ratio (exact; the survey's ratio columns).
Relative step-time between policies on the same hardware reproduces the
survey's throughput *directions* (decode is cache-bound).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.cache import CacheSpec, cache_logical_bytes_per_layer
from repro.nn import model as M

BENCH_ARCH = "paper-llama-7b"
PROMPT_LEN = 256
N_DECODE = 16
TRAIN_STEPS = 40           # brief training so attention has structure

_CACHE: dict = {}


def bench_model(n_layers: int = 4, d_model: int = 256,
                train_steps: int = TRAIN_STEPS):
    """The benchmark model (LLaMa-family, reduced) — briefly trained on the
    synthetic Markov stream so heavy-hitter structure exists and eviction
    policies differ measurably (EXPERIMENTS.md §Method)."""
    key = (n_layers, d_model, train_steps)
    if key in _CACHE:
        return _CACHE[key]
    cfg = reduced(get_config(BENCH_ARCH), num_layers=n_layers,
                  d_model=d_model, num_heads=4, num_kv_heads=4,
                  d_ff=512, vocab_size=1024)
    params = M.init_params(jax.random.key(0), cfg)
    if train_steps:
        from repro.data.synthetic import lm_batches
        from repro.optim import cosine_schedule
        from repro.train.loop import make_train_step
        init_state, step = make_train_step(
            cfg, cosine_schedule(3e-3, 5, train_steps))
        state = init_state(params)
        jstep = jax.jit(step, donate_argnums=0)
        data = lm_batches(cfg, 8, 128, seed=0)
        for _ in range(train_steps):
            state, _ = jstep(state, {k: jnp.asarray(v)
                                     for k, v in next(data).items()})
        params = state.params
    _CACHE[key] = (cfg, params)
    return cfg, params


def prompts(cfg, n: int = 2, L: int = PROMPT_LEN, seed: int = 0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, size=(n, L)),
                       jnp.int32)


@dataclass
class PolicyReport:
    name: str
    family: str
    ratio: float              # full cache bytes / policy logical bytes
    us_per_decode: float
    kl_vs_full: float
    agreement: float
    throughput_x: float = 0.0  # filled relative to "full"


def run_policy(cfg, params, spec: CacheSpec, toks, n_decode: int = N_DECODE,
               layer_budgets=None, forced_tokens=None):
    """Prefill + n_decode greedy steps.

    If `forced_tokens` (list of [B] token arrays from the full-cache run)
    is given, decode is TEACHER-FORCED on them so per-step logits are
    comparable across policies (free-running trajectories diverge
    chaotically and make agreement meaningless).
    Returns (logits list, greedy-choice list, us_per_decode)."""
    B, L = toks.shape
    prefill = jax.jit(partial(M.prefill, cfg=cfg, spec=spec,
                              layer_budgets=layer_budgets))
    decode = jax.jit(partial(M.decode_step, cfg=cfg, spec=spec))
    lg, cache = prefill(params, batch={"tokens": toks})
    logits_seq = [lg]
    tok_seq = [jnp.argmax(lg, -1)]
    def next_tok(i, lg):
        if forced_tokens is not None:
            return forced_tokens[i][:, None].astype(jnp.int32)
        return jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    tok = next_tok(0, lg)
    # warmup-compile one step, then time
    lg, cache = decode(params, cache=cache, token=tok)
    logits_seq.append(lg)
    tok_seq.append(jnp.argmax(lg, -1))
    tok = next_tok(1, lg)
    t0 = time.perf_counter()
    for i in range(n_decode - 1):
        lg, cache = decode(params, cache=cache, token=tok)
        logits_seq.append(lg)
        tok_seq.append(jnp.argmax(lg, -1))
        tok = next_tok(i + 2, lg)
    jax.block_until_ready(lg)
    dt = (time.perf_counter() - t0) / (n_decode - 1)
    return logits_seq, tok_seq, dt * 1e6


def kl_and_agreement(full_logits, full_tokens, logits, tokens):
    kls, agr = [], []
    for lf, lc, tf, tc in zip(full_logits, logits, full_tokens, tokens):
        pf = jax.nn.log_softmax(lf, -1)
        pc = jax.nn.log_softmax(lc, -1)
        kls.append(float(jnp.mean(jnp.sum(jnp.exp(pf) * (pf - pc), -1))))
        agr.append(float(jnp.mean(tf == tc)))
    return float(np.mean(kls)), float(np.mean(agr))


def ratio_for(cfg, spec: CacheSpec, total_len: int) -> float:
    full = 2 * total_len * cfg.num_kv_heads * cfg.head_dim * 2.0
    pol = cache_logical_bytes_per_layer(spec, total_len, cfg.num_kv_heads,
                                        cfg.head_dim)
    return full / pol


def fmt_csv(rows: list[PolicyReport]) -> str:
    base = next((r for r in rows if r.name == "full"), None)
    out = ["name,family,ratio,us_per_decode,throughput_x,kl_vs_full,agreement"]
    for r in rows:
        if base:
            r.throughput_x = base.us_per_decode / r.us_per_decode
        out.append(f"{r.name},{r.family},{r.ratio:.2f},{r.us_per_decode:.0f},"
                   f"{r.throughput_x:.2f},{r.kl_vs_full:.4f},"
                   f"{r.agreement:.3f}")
    return "\n".join(out)
