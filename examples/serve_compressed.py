"""End-to-end serving driver (the paper's kind: inference): batched
requests through the wave engine under every preset policy; prints the
survey's Tables 1-3 axes live.

    PYTHONPATH=src python examples/serve_compressed.py --policies h2o,kivi2
"""
import argparse

import numpy as np
import jax

from repro.configs.base import get_config, reduced
from repro.core.policy import presets
from repro.nn import model as M
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-llama-7b")
    ap.add_argument("--policies", default="full,streaming,h2o,nacl,kivi4,"
                                          "kivi2,h2o+kivi2,pyramid")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--budget", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), num_layers=4)
    params = M.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.requests, args.prompt_len)
                           ).astype(np.int32)
    src = None
    if cfg.is_encoder_decoder:
        src = rng.standard_normal(
            (args.requests, max(args.prompt_len // 4, 16), cfg.d_model)
        ).astype(np.float32)

    ps = presets(budget=args.budget, window=16, sinks=4)
    print(f"arch={args.arch} (reduced) requests={args.requests} "
          f"prompt={args.prompt_len} new={args.max_new}")
    print(f"{'policy':<12} {'family':<10} {'ratio':>6} {'prefill_s':>9} "
          f"{'tok/s':>8}")
    for name in args.policies.split(","):
        pol = ps[name]
        eng = Engine(cfg, params, pol, prompt_len=args.prompt_len,
                     max_new=args.max_new, slots=4)
        res = eng.generate(prompts, src_embeds=src)
        print(f"{name:<12} {pol.family:<10} {res.compression_ratio:>5.1f}x "
              f"{res.prefill_seconds:>9.2f} {res.decode_tokens_per_s:>8.1f}")


if __name__ == "__main__":
    main()
