"""Needle-in-a-Haystack vs cache budget (the survey's Table 1 quality
benchmark). A tiny model is first trained briefly on the synthetic stream
(so attention is meaningful), then we check whether greedy decode can
reproduce a needle planted at several depths as the cache budget shrinks.

    PYTHONPATH=src python examples/longcontext_needle.py --train-steps 60
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core.cache import CacheSpec
from repro.data.synthetic import needle_prompt
from repro.data.synthetic import lm_batches
from repro.nn import model as M
from repro.optim import cosine_schedule
from repro.train.loop import make_train_step


def copy_accuracy(cfg, params, spec, prompt, value, layer_budgets=None):
    """Greedy-decode len(value) tokens after the final MARKER; a model with
    the needle in cache should echo it (copy induction is learnable from
    the Markov stream's repetition)."""
    toks = jnp.asarray(prompt)[None]
    lg, cache = M.prefill(params, cfg, {"tokens": toks}, spec,
                          layer_budgets=layer_budgets)
    hits = 0
    tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    for i in range(len(value)):
        hits += int(tok[0, 0]) == int(value[i])
        lg, cache = M.decode_step(params, cfg, cache, tok, spec)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    return hits / len(value)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--length", type=int, default=256)
    args = ap.parse_args()

    cfg = reduced(get_config("paper-llama-7b"), num_layers=4, d_model=256,
                  num_heads=4, num_kv_heads=4, d_ff=512, vocab_size=512)
    params = M.init_params(jax.random.key(0), cfg)
    init_state, step = make_train_step(cfg, cosine_schedule(3e-3, 10, 200))
    state = init_state(params)
    data = lm_batches(cfg, 8, 128, seed=0)
    jstep = jax.jit(step, donate_argnums=0)
    for i in range(args.train_steps):
        state, m = jstep(state, {k: jnp.asarray(v)
                                 for k, v in next(data).items()})
    params = state.params
    print(f"trained {args.train_steps} steps, ce={float(m.ce_loss):.3f}")

    L = args.length
    print(f"{'policy/budget':<22} {'depth=0.2':>9} {'depth=0.8':>9}")
    for name, budget in [("full", 0), ("h2o", L // 2), ("h2o", L // 4),
                         ("streaming", L // 4)]:
        if budget == 0:
            spec = CacheSpec(budget=L + 16, policy="none")
        else:
            spec = CacheSpec(budget=budget, window=16, sinks=4, policy=name,
                             group=16, recent_protect=16)
        accs = []
        for depth in (0.2, 0.8):
            prompt, value, marker = needle_prompt(cfg.vocab_size, L,
                                                  depth=depth, seed=3)
            accs.append(copy_accuracy(cfg, params, spec, prompt, value))
        tag = f"{name}@{budget or L + 16}"
        print(f"{tag:<22} {accs[0]:>9.2f} {accs[1]:>9.2f}")


if __name__ == "__main__":
    main()
