"""End-to-end training driver: train a small LLaMa-family model on the
synthetic Markov stream and watch the loss drop; checkpoints on exit.

Default size is CPU-friendly (~3M params, 200 steps, a few minutes);
--preset 100m selects a ~100M model for real hardware.

    PYTHONPATH=src python examples/train_tiny.py --steps 200
"""
import argparse
import time

import jax

from repro.configs.base import get_config, reduced
from repro.data.synthetic import lm_batches
from repro.nn import model as M
from repro.optim import wsd_schedule
from repro.train.loop import make_train_step
from repro.checkpoint import save_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    base = get_config("paper-llama-7b")
    if args.preset == "tiny":
        cfg = reduced(base, num_layers=4, d_model=256, num_heads=4,
                      num_kv_heads=4, d_ff=512, vocab_size=512)
    else:  # ~100M
        cfg = base.replace(num_layers=12, d_model=768, num_heads=12,
                           num_kv_heads=12, d_ff=2048, vocab_size=32000)

    params = M.init_params(jax.random.key(0), cfg)
    # MiniCPM-style WSD schedule (survey-adjacent substrate requirement)
    lr = wsd_schedule(3e-3, warmup=20, stable=args.steps // 2,
                      decay=args.steps // 3)
    init_state, train_step = make_train_step(cfg, lr)
    state = init_state(params)
    step_fn = jax.jit(train_step, donate_argnums=0)

    data = lm_batches(cfg, args.batch, args.seq, seed=0)
    first = last = None
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
        state, m = step_fn(state, batch)
        if i == 0:
            first = float(m.ce_loss)
        if i % 20 == 0 or i == args.steps - 1:
            last = float(m.ce_loss)
            print(f"step {i:4d}  ce={last:.4f}  lr={float(m.lr):.2e}  "
                  f"gnorm={float(m.grad_norm):.2f}  "
                  f"({(time.perf_counter() - t0):.0f}s)", flush=True)
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'no improvement'})")
    if args.ckpt:
        save_pytree(state, args.ckpt)
        print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
