"""Quickstart: the survey's subject in 60 seconds.

Builds a small LLaMa-family model, serves the same prompts under four
cache policies (full / H2O eviction / KIVI 2-bit / hybrid), and prints
the survey's comparison axes: compression ratio, decode speed, agreement.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.policy import presets
from repro.nn import model as M
from repro.serving import Engine

import jax


def main():
    cfg = reduced(get_config("paper-llama-7b"), num_layers=4)
    params = M.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(4, 128)).astype(np.int32)

    ps = presets(budget=48, window=16, sinks=4)
    ref_tokens = None
    print(f"{'policy':<12} {'ratio':>6} {'tok/s':>8} {'free-run agree':>14}")
    for name in ("full", "h2o", "kivi2", "h2o+kivi2"):
        eng = Engine(cfg, params, ps[name], prompt_len=128, max_new=16,
                     slots=4)
        res = eng.generate(prompts)
        if ref_tokens is None:
            ref_tokens = res.tokens
        agree = float((res.tokens == ref_tokens).mean())
        print(f"{name:<12} {res.compression_ratio:>5.1f}x "
              f"{res.decode_tokens_per_s:>8.1f} {agree:>14.2f}")
    print("\nnotes: free-running trajectories diverge chaotically on an "
          "untrained model — see benchmarks/ for teacher-forced quality; "
          "quantized tok/s is jnp-dequant-bound on CPU (the fused Pallas "
          "kernel covers the TPU target).")


if __name__ == "__main__":
    main()
